"""Adaptive-ω benchmark: online redundancy control vs. static ω.

Sweeps static redundancy ratios and the adaptive policies
(:mod:`repro.runtime.adaptive`) over three straggler regimes on the real
master/worker/fusion engine, and reports per-variant resolution-0 mean
delay and deadline success rate (fraction of jobs releasing at least
resolution 0 before §IV termination):

  stationary  exp stragglers, nothing changes — adaptation should cost
              nothing (static and adaptive land within noise).
  shift       a worker goes dark mid-run ("shift" injection): the regime
              the controller exists for.  Low static ω starves fusion
              after the shift (with T = k every worker's task is
              critical); the controller grows ω the moment rounds miss.
  burst       the worker goes dark for ``burst_len`` seconds of every
              ``burst_period`` ("burst" injection): the controller must
              grow into bursts and may shrink between them.

The ISSUE/acceptance verdict is evaluated on the shift scenario: the
adaptive policy must be within noise of the BEST static ω and strictly
better than the WORST static ω on deadline success rate, with res-0 mean
delay within noise of the best static.  Every variant runs against the
same arrival trace and the same wall-clock regime timeline, so the
comparison is apples-to-apples.

Run:  PYTHONPATH=src python benchmarks/bench_adaptive_omega.py --jobs 120
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import time

import numpy as np

from repro.runtime import RuntimeConfig, run_jobs

MU = (385.95, 650.92, 373.40, 415.75, 373.98)   # the paper's §IV cluster

#: Static redundancy grid.  At omega=1.0 the codeword has no slack
#: (T = k = 4) and the eq. (1) split leaves a coded task on the worker
#: that the shift/burst regimes stall — the worst case the controller
#: must escape.  omega=1.5 is the repo-default provisioning; omega=2.0
#: is over-provisioned.
STATIC_OMEGAS = (1.0, 1.5, 2.0)
ADAPTIVE_POLICIES = ("aimd", "deadline-margin")

#: Noise tolerances for the verdict: success rates are job fractions
#: (threaded run, ~hundreds of jobs), delays carry timer-granularity
#: jitter per round.
SUCCESS_TOL = 0.05
DELAY_TOL = 0.30


def scenario_base(name: str, jobs: int) -> RuntimeConfig:
    """The shared cluster/workload for one scenario (omega/adapt vary)."""
    # Expected span: jobs / arrival_rate seconds; regime boundaries sit
    # mid-run so every variant sees both regimes for ~half its jobs.
    span = jobs / 12.0
    if name == "stationary":
        return RuntimeConfig(mu=MU, arrival_rate=12.0, complexity=10.0,
                             deadline=0.035, straggler="exp", seed=7)
    if name == "shift":
        # worker 1 (the fastest — always holds coded tasks) goes dark
        return RuntimeConfig(mu=MU, arrival_rate=12.0, complexity=10.0,
                             deadline=0.035, straggler="shift",
                             stall_workers=(1,), shift_at=span / 2,
                             stall_seconds=1.0, seed=7)
    if name == "burst":
        return RuntimeConfig(mu=MU, arrival_rate=12.0, complexity=10.0,
                             deadline=0.035, straggler="burst",
                             stall_workers=(1,), burst_period=span / 3,
                             burst_len=span / 6, stall_seconds=1.0, seed=7)
    raise ValueError(f"unknown scenario {name!r}")


def run_variant(cfg: RuntimeConfig, jobs: int) -> dict:
    t0 = time.perf_counter()
    result, _ = run_jobs(cfg, jobs, K=64, M=8, N=8)
    wall = time.perf_counter() - t0
    md = result.mean_delay()
    sr = result.success_rate()
    ctl = result.controller or {}
    return {
        "adapt": cfg.adapt,
        "omega": cfg.omega,
        "omega_final": ctl.get("omega_final", cfg.omega),
        "res0_mean_delay": float(md[0]),
        "res0_success_rate": float(sr[0]),
        "final_success_rate": float(sr[-1]),
        "terminated": int(result.terminated.sum()),
        "stale_results": int(result.stale_results),
        "retunes": int(ctl.get("retunes", 0)),
        "switches": int(ctl.get("switches", 0)),
        "prime_seconds_total": float(ctl.get("prime_seconds_total", 0.0)),
        "wall_seconds": round(wall, 2),
    }


def variant_label(row: dict) -> str:
    if row["adapt"] == "fixed":
        return f"static w={row['omega']:.2f}"
    return f"adapt {row['adapt']} (w {row['omega']:.2f}->" \
           f"{row['omega_final']:.2f})"


def verdict(static_rows: list[dict], adaptive_rows: list[dict]) -> dict:
    """The acceptance comparison: adaptive vs best/worst static ω.

    Best/worst static are chosen by res-0 success rate (ties broken by
    res-0 mean delay) — the §IV metric the deadline system optimizes.
    """
    key = lambda r: (r["res0_success_rate"], -r["res0_mean_delay"])
    best = max(static_rows, key=key)
    worst = min(static_rows, key=key)
    # When even the worst static omega succeeds near-always (stationary
    # regimes), there is no gap to strictly beat; the verdict then rests
    # on matching the best.  The flag is reported honestly as its own
    # field rather than folded into "strictly beats".
    worst_beatable = worst["res0_success_rate"] <= 1.0 - SUCCESS_TOL
    out = {"best_static_omega": best["omega"],
           "worst_static_omega": worst["omega"],
           "worst_static_beatable": bool(worst_beatable), "policies": {}}
    for row in adaptive_rows:
        ok_success_best = (row["res0_success_rate"]
                           >= best["res0_success_rate"] - SUCCESS_TOL)
        ok_delay_best = (row["res0_mean_delay"]
                         <= best["res0_mean_delay"] * (1 + DELAY_TOL))
        beats_worst = (row["res0_success_rate"]
                       > worst["res0_success_rate"] + SUCCESS_TOL)
        out["policies"][row["adapt"]] = {
            "within_noise_of_best_static": bool(ok_success_best
                                                and ok_delay_best),
            "strictly_beats_worst_static": bool(beats_worst),
            "pass": bool(ok_success_best and ok_delay_best
                         and (beats_worst or not worst_beatable)),
        }
    return out


def run_scenario(name: str, jobs: int) -> dict:
    base = scenario_base(name, jobs)
    print(f"\n== {name}: {jobs} jobs/variant, straggler={base.straggler}, "
          f"deadline={base.deadline} ==")
    static_rows, adaptive_rows = [], []
    for omega in STATIC_OMEGAS:
        static_rows.append(run_variant(
            dataclasses.replace(base, omega=omega), jobs))
    for policy in ADAPTIVE_POLICIES:
        # adaptive variants start at the WORST provisioning (omega_min) and
        # must earn their redundancy from the runtime signals alone
        adaptive_rows.append(run_variant(
            dataclasses.replace(base, omega=1.0, adapt=policy), jobs))
    head = (f"{'variant':>34} {'res0 delay':>11} {'res0 succ':>10} "
            f"{'final succ':>10} {'term':>5} {'stale':>6} {'switch':>6}")
    print(head)
    for row in static_rows + adaptive_rows:
        print(f"{variant_label(row):>34} {row['res0_mean_delay']:>11.4f} "
              f"{row['res0_success_rate']:>10.3f} "
              f"{row['final_success_rate']:>10.3f} {row['terminated']:>5} "
              f"{row['stale_results']:>6} {row['switches']:>6}")
    v = verdict(static_rows, adaptive_rows)
    print(f"best static w={v['best_static_omega']}, "
          f"worst static w={v['worst_static_omega']}"
          + ("" if v["worst_static_beatable"]
             else " (near-perfect: no strict gap to beat)"))
    for policy, res in v["policies"].items():
        print(f"  {policy}: within noise of best={res['within_noise_of_best_static']}, "
              f"beats worst={res['strictly_beats_worst_static']} -> "
              f"{'PASS' if res['pass'] else 'FAIL'}")
    return {"name": name, "jobs": jobs, "deadline": base.deadline,
            "straggler": base.straggler, "static": static_rows,
            "adaptive": adaptive_rows, "verdict": v}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--jobs", type=int, default=120,
                    help="jobs per variant (5 variants per scenario)")
    ap.add_argument("--scenarios", default="stationary,shift,burst",
                    help="comma list from {stationary, shift, burst}")
    ap.add_argument("--out", default="BENCH_adaptive_omega.json")
    args = ap.parse_args(argv)

    names = [s for s in args.scenarios.split(",") if s]
    report = {"bench": "adaptive_omega", "jobs_per_variant": args.jobs,
              "static_omegas": list(STATIC_OMEGAS),
              "adaptive_policies": list(ADAPTIVE_POLICIES),
              "scenarios": [run_scenario(n, args.jobs) for n in names]}
    path = pathlib.Path(args.out)
    path.write_text(json.dumps(report, indent=2))
    print(f"\nwrote {path}")
    # exit nonzero if the shift acceptance verdict fails for every policy
    shift = [s for s in report["scenarios"] if s["name"] == "shift"]
    if shift and not any(p["pass"]
                         for p in shift[0]["verdict"]["policies"].values()):
        print("ACCEPTANCE FAIL: no adaptive policy passed the shift verdict")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
