"""Coded-matmul benchmark: encode/compute/decode throughput + erasure sweep.

Measures the end-to-end layered coded pipeline (the system the queueing
simulator models in time) and the decode-anywhere property across erasure
counts — one row per (omega, erasures) with us/call and relative error.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.layered_matmul import LayeredCodedMatmul


def main():
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.normal(size=(512, 64)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(512, 64)), jnp.float32)
    exact = np.asarray(A.T @ B)

    print("name,us_per_call,derived")
    for omega in (1.0, 1.25, 1.5, 2.0):
        pipe = LayeredCodedMatmul(m=2, d=8, n1=2, n2=2, omega=omega)
        max_erase = pipe.code.num_tasks - pipe.code.k
        for n_erase in sorted({0, max_erase // 2, max_erase}):
            erasures = list(range(n_erase))
            t0 = time.perf_counter()
            iters = 3
            for _ in range(iters):
                res, _ = pipe.run(A, B, erasures=erasures)
            dt = (time.perf_counter() - t0) / iters
            err = np.abs(res[-1] - exact).max() / np.abs(exact).max()
            print(f"coded_matmul omega={omega} erased={n_erase}/"
                  f"{pipe.code.num_tasks},{dt * 1e6:.0f},"
                  f"rel_err={err:.1e}")


if __name__ == "__main__":
    main()
