"""Micro-benchmarks for the master's per-round coding hot path.

Three measurements, each new-vs-legacy so the speedup is measured, not
asserted:

  1. float decode — cached :class:`~repro.core.coding.DecodePlan`
     (indexed Vandermonde + cached solve operator + vectorized block
     reassembly) vs the pre-plan path (``np.vander`` + ``np.linalg.solve``
     + Python concatenate loop per fuse);
  2. float encode — cached per-geometry encode basis vs rebuilding the
     point-power matrices on every round;
  3. gfp encode — vectorized ``_mod_combine`` (einsum digit accumulation)
     vs the former per-plane Python loop.

Run:  PYTHONPATH=src python benchmarks/bench_coding_hotpath.py
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

from repro.core import coding


def _bench(fn, iters: int) -> float:
    fn()                       # warm caches / BLAS
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


# -- legacy reference implementations (the pre-DecodePlan hot path) ---------

def _legacy_float_decode(code, task_ids, results):
    ids = list(task_ids)[: code.k]
    res = np.asarray(results)[: code.k]
    pts = code.points()[np.asarray(ids)]
    V = np.vander(pts, N=code.k, increasing=True)
    coeffs = np.linalg.solve(V, res.reshape(code.k, -1))
    coeffs = coeffs.reshape(code.k, *res.shape[1:])
    rows = []
    for r in range(code.n1):
        cols = [coeffs[r + s * code.n1] for s in range(code.n2)]
        rows.append(np.concatenate(cols, axis=1))
    return np.concatenate(rows, axis=0)


def _legacy_float_basis(code):
    pts = code.points()
    va = np.stack([pts**r for r in range(code.n1)], 0)
    vb = np.stack([pts ** (s * code.n1) for s in range(code.n2)], 0)
    return va, vb


def _legacy_mod_combine(blocks, vand, p):
    n = blocks.shape[0]
    vh, vl = vand >> np.uint64(16), vand & np.uint64(0xFFFF)
    bh, bl = blocks >> np.uint64(16), blocks & np.uint64(0xFFFF)
    two16, two32 = (1 << 16) % p, (1 << 32) % p
    out = np.zeros((vand.shape[1],) + blocks.shape[1:], dtype=np.uint64)
    for r in range(n):
        hh = (bh[r][None] * vh[r][:, None, None]) % p
        hl = (bh[r][None] * vl[r][:, None, None]) % p
        lh = (bl[r][None] * vh[r][:, None, None]) % p
        ll = (bl[r][None] * vl[r][:, None, None]) % p
        term = (hh * two32 + (hl + lh) * two16 + ll) % p
        out = (out + term) % p
    return out


def run(iters: int = 2000, K: int = 64, M: int = 8, N: int = 8,
        seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    report = {}

    # 1. float decode: plan vs legacy, identical inputs
    code = coding.PolynomialCode(n1=2, n2=2, omega=1.5)
    a = rng.integers(0, 255, size=(K, M)).astype(np.float64)
    b = rng.integers(0, 255, size=(K, N)).astype(np.float64)
    X, Y = code.encode(a, b)
    ids = [5, 0, 3, 1]         # out-of-order arrivals, like a real fuse
    res = np.stack([X[t].T @ Y[t] for t in ids])
    t_plan = _bench(lambda: code.decode(ids, res), iters)
    t_leg = _bench(lambda: _legacy_float_decode(code, ids, res), iters)
    np.testing.assert_allclose(code.decode(ids, res),
                               _legacy_float_decode(code, ids, res),
                               rtol=1e-9, atol=1e-9)
    report["float_decode"] = {"plan_us": t_plan * 1e6,
                              "legacy_us": t_leg * 1e6,
                              "speedup": t_leg / t_plan}

    # 2. float encode: cached basis + per-side amortization vs per-round
    # full rebuild.  The pipelined master memoizes each operand side, so
    # one job's m**2 rounds cost m A-side + m B-side encodes total.
    m = 2                      # the default RuntimeConfig plane count
    t_enc = _bench(lambda: code.encode(a, b), iters)
    t_side = _bench(lambda: (code.encode_a(a), code.encode_b(b)), iters)

    def legacy_encode():
        va, vb = _legacy_float_basis(code)
        blocks_a = np.stack(np.split(a, code.n1, axis=1), axis=0)
        blocks_b = np.stack(np.split(b, code.n2, axis=1), axis=0)
        X = np.einsum("rkm,rt->tkm", blocks_a, va)
        Y = np.einsum("skn,st->tkn", blocks_b, vb)
        return X, Y

    t_enc_leg = _bench(legacy_encode, iters)
    t_enc_round = t_side * m / (m * m)     # amortized per round
    report["float_encode"] = {"cached_us": t_enc * 1e6,
                              "legacy_us": t_enc_leg * 1e6,
                              "amortized_per_round_us": t_enc_round * 1e6,
                              "speedup": t_enc_leg / t_enc}

    # 3. gfp encode: vectorized _mod_combine vs per-plane Python loop
    gcode = coding.PolynomialCode(n1=4, n2=1, omega=1.5, mode="gfp")
    ga = rng.integers(0, coding.MERSENNE_P, size=(K, 8),
                      dtype=np.uint64)
    blocks = np.stack(np.split(ga, 4, axis=1), axis=0)
    va, _ = coding._encode_basis(gcode)
    new = coding._mod_combine(blocks, va, gcode.p)
    old = _legacy_mod_combine(blocks, va, gcode.p)
    np.testing.assert_array_equal(new, old)
    t_new = _bench(lambda: coding._mod_combine(blocks, va, gcode.p), iters)
    t_old = _bench(lambda: _legacy_mod_combine(blocks, va, gcode.p), iters)
    report["gfp_mod_combine"] = {"vectorized_us": t_new * 1e6,
                                 "legacy_us": t_old * 1e6,
                                 "speedup": t_old / t_new}

    # the ISSUE's headline: master-side per-round overhead (encode+decode)
    per_round_new = t_enc_round + t_plan
    per_round_leg = t_enc_leg + t_leg
    report["per_round_encode_plus_decode"] = {
        "new_us": per_round_new * 1e6, "legacy_us": per_round_leg * 1e6,
        "speedup": per_round_leg / per_round_new}
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--iters", type=int, default=2000)
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)
    report = run(iters=args.iters)
    for name, row in report.items():
        vals = "  ".join(f"{k}={v:.2f}" for k, v in row.items())
        print(f"{name:>28}: {vals}")
    if args.json:
        pathlib.Path(args.json).write_text(json.dumps(report, indent=2))
        print(f"wrote {args.json}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
