"""Render the roofline table (EXPERIMENTS.md §Roofline) from dry-run JSONs."""

from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str = "single"):
    recs = []
    for fn in glob.glob(os.path.join(RESULTS, f"*__{mesh}.json")):
        with open(fn) as f:
            recs.append(json.load(f))
    recs = [r for r in recs if r.get("status") == "ok"]
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    return recs


def fmt_row(r):
    t = [r["compute_s"], r["memory_s"], r["collective_s"]]
    frac = r.get("roofline_fraction", 0.0)
    mfr = r.get("model_flops_ratio", 0.0)
    mem = r.get("memory_analysis", {})
    peak = mem.get("temp_size_in_bytes", 0) / 2**30 if isinstance(mem, dict) \
        else 0
    return (f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {t[0]:.4g} | {t[1]:.4g} | {t[2]:.4g} | {r['bound']} "
            f"| {mfr:.2f} | {frac:.3f} | {peak:.1f} |")


def table(mesh: str = "single") -> str:
    lines = [
        "| arch | shape | kind | compute_s | memory_s | collective_s "
        "| bound | 6ND/HLO | roofline frac | temp GiB |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load(mesh):
        lines.append(fmt_row(r))
    return "\n".join(lines)


def summary(mesh: str = "single") -> dict:
    recs = load(mesh)
    worst = min((r for r in recs if r.get("roofline_fraction")),
                key=lambda r: r["roofline_fraction"])
    most_coll = max(recs, key=lambda r: r["collective_s"]
                    / max(r["compute_s"] + r["memory_s"], 1e-12))
    return {"num_cells": len(recs), "worst_fraction": worst,
            "most_collective_bound": most_coll}


if __name__ == "__main__":
    print(table("single"))
    print()
    s = summary("single")
    print(f"cells: {s['num_cells']}")
    w = s["worst_fraction"]
    print(f"worst roofline fraction: {w['arch']} x {w['shape']} "
          f"({w['roofline_fraction']:.4f})")
    c = s["most_collective_bound"]
    print(f"most collective-bound: {c['arch']} x {c['shape']} "
          f"(coll {c['collective_s']:.3f}s vs compute {c['compute_s']:.3f}s)")
