"""Runtime engine benchmark: measured delay-per-resolution tables.

Runs the real master/worker/fusion engine on three §IV-style scenarios and
emits the paper's Fig.-style per-resolution table for each, plus a JSON
artifact (``BENCH_runtime.json`` by default) with every row — the CI smoke
artifact.

Scenarios:
  open      exp stragglers, no deadline  (delay ordering res0 < .. < final)
  deadline  exp stragglers + deadline    (termination releases partials)
  stall     one stalled worker + deadline (redundancy carries the round)

Run:  PYTHONPATH=src python benchmarks/bench_runtime.py --jobs 200
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

from repro.core import simulator
from repro.runtime import (RuntimeConfig, delay_table, format_delay_table,
                           format_stage_table, run_jobs)

MU = (385.95, 650.92, 373.40, 415.75, 373.98)   # the paper's §IV cluster


def scenarios(jobs: int) -> list[dict]:
    return [
        dict(name="open", jobs=jobs,
             cfg=RuntimeConfig(mu=MU, arrival_rate=10.0, complexity=10.0,
                               straggler="exp", seed=0)),
        dict(name="deadline", jobs=jobs,
             cfg=RuntimeConfig(mu=MU, arrival_rate=12.0, complexity=10.0,
                               deadline=0.035, straggler="exp", seed=1)),
        # stall worker 4 (kappa_4 = 1 of T = 6): redundancy carries rounds
        dict(name="stall", jobs=jobs,
             cfg=RuntimeConfig(mu=MU, arrival_rate=12.0, complexity=10.0,
                               deadline=0.050, straggler="stall",
                               stall_workers=(4,), stall_seconds=2.0,
                               seed=2)),
    ]


def run_scenario(spec: dict, *, sim_jobs: int) -> dict:
    cfg = spec["cfg"]
    t0 = time.perf_counter()
    result, _ = run_jobs(cfg, spec["jobs"], K=64, M=8, N=8, verify=True)
    wall = time.perf_counter() - t0
    sim = simulator.simulate(cfg.to_system_config(), sim_jobs, layered=True,
                             deadline=cfg.deadline, seed=cfg.seed)
    rows = delay_table(result)
    sim_rows = delay_table(sim)
    errs = result.verify_errors[np.isfinite(result.verify_errors)]
    max_err = f"{errs.max():.2e}" if errs.size else "n/a"
    print(f"\n== {spec['name']}: {spec['jobs']} jobs, straggler="
          f"{cfg.straggler}, deadline={cfg.deadline} "
          f"({wall:.1f} s wall) ==")
    print(f"kappa={result.kappa.tolist()} "
          f"terminated={int(result.terminated.sum())}/{result.num_jobs} "
          f"release_hist={result.release_histogram().tolist()} "
          f"util={np.round(result.utilization, 3).tolist()} "
          f"max_verify_rel_err={max_err}")
    print("measured:")
    print(format_delay_table(rows))
    print("per-stage master pipeline timings:")
    print(format_stage_table(result))
    print(f"simulated ({sim_jobs} jobs):")
    print(format_delay_table(sim_rows))
    return {
        "name": spec["name"],
        "jobs": spec["jobs"],
        "straggler": cfg.straggler,
        "deadline": cfg.deadline,
        "kappa": [int(x) for x in result.kappa],
        "terminated": int(result.terminated.sum()),
        "release_histogram": [int(x) for x in result.release_histogram()],
        "worker_utilization": [round(float(u), 4)
                               for u in result.utilization],
        "stale_results": int(result.stale_results),
        "max_verify_rel_error": float(errs.max()) if errs.size else None,
        "measured_delay_per_resolution": rows,
        "simulated_delay_per_resolution": sim_rows,
        "stage_seconds": {k: round(float(v), 6)
                          for k, v in (result.stage_seconds or {}).items()},
        "stage_rounds": int(result.stage_rounds),
        "master_overhead_us_per_round": round(
            result.per_round_overhead() * 1e6, 2),
        "wall_seconds": round(wall, 2),
    }


def run_tracing_overhead(jobs: int = 24, reps: int = 3) -> dict:
    """Tracing cost row: the same no-straggler workload, traced vs not.

    No injected delays and a saturating arrival rate, so wall time is
    nearly all per-round engine overhead — the worst case for tracing,
    whose cost is per event, not per second of injected delay.  Each
    variant takes the min wall over ``reps`` runs (noise floor), and the
    row reports per-round microseconds for both plus the delta the CI
    gate bounds (disabled: within noise of the pre-telemetry engine;
    enabled: < 50 us/round).
    """
    walls = {}
    rounds = events = 0
    for trace in (False, True):
        cfg = RuntimeConfig(mu=MU, arrival_rate=500.0, complexity=1.0,
                            straggler="none", trace=trace, seed=3)
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            result, _ = run_jobs(cfg, jobs, K=64, M=8, N=8, verify=False)
            best = min(best, time.perf_counter() - t0)
        walls[trace] = best
        rounds = result.stage_rounds
        if trace:
            events = len(result.trace_events or ())
            assert result.trace_dropped == 0
    per_round = {t: walls[t] / rounds * 1e6 for t in walls}
    delta = per_round[True] - per_round[False]
    print(f"\n== tracing overhead: {jobs} jobs x {reps} reps, "
          f"{rounds} rounds/run ==")
    print(f"trace off: {per_round[False]:8.1f} us/round")
    print(f"trace on:  {per_round[True]:8.1f} us/round  "
          f"({events} events/run)")
    print(f"delta:     {delta:+8.1f} us/round")
    return {
        "jobs": jobs, "reps": reps, "rounds": rounds,
        "events_per_run": events,
        "per_round_us_disabled": round(per_round[False], 2),
        "per_round_us_enabled": round(per_round[True], 2),
        "overhead_us_per_round": round(delta, 2),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--jobs", type=int, default=200,
                    help="jobs per scenario (CI smoke uses 200)")
    ap.add_argument("--sim-jobs", type=int, default=4000)
    ap.add_argument("--out", default="BENCH_runtime.json")
    args = ap.parse_args(argv)

    report = {"bench": "runtime", "jobs_per_scenario": args.jobs,
              "scenarios": [run_scenario(s, sim_jobs=args.sim_jobs)
                            for s in scenarios(args.jobs)],
              "tracing_overhead": run_tracing_overhead()}
    path = pathlib.Path(args.out)
    path.write_text(json.dumps(report, indent=2))
    print(f"\nwrote {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
