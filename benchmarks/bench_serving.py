"""Serving-gateway benchmark: deadline conformance under open load.

Drives the multi-tenant :class:`~repro.runtime.gateway.ServingGateway`
(one warm thread-backend fleet, G/G/1 admission) with open request
streams and measures the paper's serving claims:

1. **Load x traffic sweep** — Poisson and bursty arrivals at several
   load factors (``rho`` targets relative to the fleet's *measured*
   full-resolution service time): admission decisions (admit /
   down-resolve / reject), per-resolution deadline-success rates, mean
   slack and queue wait.  Bursty traffic at the same mean rate carries a
   higher arrival SCV, so the G/G/1 bound prices it more conservatively
   — visible as more down-resolves at equal load.
2. **The Fig. 5 serving cell** — ~150 Poisson requests with a deadline
   sized *between* the res-0 and next-to-final G/G/1 delay estimates
   (service share + Marchal waiting time, safety-inflated — the same
   numbers the admission bound prices), so the full computation cannot
   be admitted against the deadline while resolution 0 is, and lands
   for >= 99% of requests: layered release rescues a deadline the
   monolithic job misses.  The claim is checked locally and gates the
   run under ``--strict``.

Deadlines and rates are derived from a serial calibration phase (the
fleet's own measured service moments), so the benchmark lands in the
same regime on fast and slow machines alike.

Emits ``BENCH_serving.json``.

Run:  PYTHONPATH=src python benchmarks/bench_serving.py --requests 150
"""

from __future__ import annotations

import argparse
import gc
import json
import pathlib
import time
from typing import Optional

import numpy as np

from repro.core.layering import cumulative_minijobs
from repro.core.queueing import Moments, gg1_waiting_time
from repro.launch.serve_gateway import request_gaps
from repro.runtime import RuntimeConfig, ServingGateway

MU = (385.95, 650.92, 373.40, 415.75, 373.98)   # the paper's §IV cluster

#: target utilization of the Fig. 5 cell (full-resolution-equivalent:
#: the load the admission bound models, not the post-down-resolve one)
FIG5_LOAD = 0.15


def _cfg(args: argparse.Namespace, rate: float) -> RuntimeConfig:
    return RuntimeConfig(
        mu=MU, arrival_rate=rate, n1=2, n2=2, omega=1.5, m=args.planes,
        d=8, complexity=args.complexity, straggler=args.straggler,
        backend="thread", seed=args.seed)


def _operands(rng: np.random.Generator, cfg: RuntimeConfig, K: int):
    lim = 1 << (cfg.m * cfg.d - 2)
    a = rng.integers(-lim, lim, size=(K, 8), dtype=np.int64)
    b = rng.integers(-lim, lim, size=(K, 8), dtype=np.int64)
    return a, b


def calibrate(args: argparse.Namespace) -> tuple[Moments, list]:
    """Measured service moments from serial res-0-capped requests.

    Samples are normalized to full-resolution equivalents by ``m**2``
    — the *same* normalization the gateway applies when it feeds its
    admission controller (``m**2 / cum(l)``), so the deadline sized
    from these moments sits on the exact scale the online bound will
    price, with no drift between calibration and serving.
    """
    cfg = _cfg(args, rate=1.0)
    rng = np.random.default_rng(args.seed)
    m2 = args.planes * args.planes
    warmup = 2   # cold-fleet samples (thread spin-up) are not serving-regime
    with ServingGateway(cfg, admission="none") as gw:
        tickets = [gw.submit(*_operands(rng, cfg, args.K), deadline=60.0,
                             resolution=0, min_resolution=0)
                   for _ in range(args.calibration + warmup)]
        if not all(t.wait(timeout=120.0) for t in tickets):
            raise RuntimeError("calibration requests never released")
    svc = m2 * np.array([t.result.released_at - t.result.service_started_at
                         for t in tickets[warmup:]])
    samples = [float(s) for s in svc]
    return Moments(float(svc.mean()), float(np.square(svc).mean())), samples


def size_deadline(args: argparse.Namespace, service: Moments,
                  rate: float) -> float:
    """A deadline that forces the G/G/1 bound to down-resolve every
    request to res-0 yet still admit it: the geometric mean of the
    safety-inflated res-0 estimate (with one queued res-0 job of
    backlog allowance — a request arriving behind one in-service res-0
    job must still clear the bound) and the next-to-final resolution's
    estimate, leaving symmetric margins against moment drift.  Any
    estimate at or above next-to-final — the full resolution included —
    then never fits the deadline.
    """
    arrival = Moments(1.0 / rate, 2.0 / (rate * rate))   # Poisson
    w = gg1_waiting_time(arrival, service)
    cum = cumulative_minijobs(args.planes)
    m2 = args.planes * args.planes
    res0 = service.mean * cum[0] / m2
    lo = w + 2.0 * res0                          # res-0 + backlog allowance
    hi = w + service.mean * cum[-2] / m2         # next-to-final share
    return args.safety * float(np.sqrt(lo * hi))


def serve_stream(args: argparse.Namespace, *, rate: float, traffic: str,
                 deadline: Optional[float], requests: int, seed: int,
                 seed_service=()) -> dict:
    """One open-stream run; returns the gateway's outcome summary.

    ``seed_service`` (full-resolution-equivalent seconds, e.g. the
    calibration samples) pre-feeds the admission controller's service
    window so the bound prices the measured fleet from the first
    request instead of running its modeled priors warm.

    ``deadline=None`` re-sizes each request's deadline from the
    controller's *current* measured service moments (the same
    :func:`size_deadline` band) — pinning the Fig. 5 regime against
    machine-speed drift between calibration and serving.
    """
    cfg = _cfg(args, rate=rate)
    rng = np.random.default_rng(seed)
    gaps = request_gaps(traffic, rate, requests, rng)
    deadlines = []
    # a gen-2 GC pause mid-round reads as a tens-of-ms straggler the
    # admission bound never priced: collect up front, defer the rest
    gc.collect()
    gc.disable()
    t0 = time.perf_counter()
    try:
        with ServingGateway(cfg, admission=args.admission,
                            safety=args.safety) as gw:
            for s in seed_service:
                gw.admission.note_service(s)
            tickets = []
            for g in gaps:
                time.sleep(float(g))
                d = (deadline if deadline is not None
                     else size_deadline(args,
                                        gw.admission.service_moments(),
                                        rate))
                deadlines.append(d)
                tickets.append(gw.submit(*_operands(rng, cfg, args.K),
                                         deadline=d, min_resolution=0))
            for t in tickets:
                t.wait(timeout=120.0)
    finally:
        gc.enable()
    wall = time.perf_counter() - t0
    stats = gw.stats
    stats.reconcile()
    js = stats.to_json()
    waits = [w for w in stats.queue_waits if w is not None]
    gaps_meas = np.diff([t.arrival for t in tickets])
    arrival = (Moments(float(np.mean(gaps_meas)),
                       float(np.mean(np.square(gaps_meas))))
               if len(gaps_meas) >= 2 else None)
    return {
        "traffic": traffic,
        "rate_per_s": round(rate, 3),
        "deadline_ms": round(float(np.mean(deadlines)) * 1e3, 3),
        "deadline_tracked": deadline is None,
        "requests": requests,
        "wall_seconds": round(wall, 3),
        "admitted": stats.admitted,
        "down_resolved": stats.down_resolved,
        "rejected": stats.rejected,
        "degraded": stats.degraded,
        "release_histogram": js["release_histogram"],
        "deadline_success": js["deadline_success"],
        "mean_slack_ms": (None if js["mean_slack"] is None
                          else round(js["mean_slack"] * 1e3, 3)),
        "mean_queue_wait_ms": (None if not waits
                               else round(float(np.mean(waits)) * 1e3, 3)),
        "arrival_scv": (None if arrival is None
                        else round(arrival.scv, 3)),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=150,
                    help="requests in the Fig. 5 cell")
    ap.add_argument("--sweep-requests", type=int, default=60,
                    help="requests per load-sweep row")
    ap.add_argument("--loads", default="0.3,0.6,0.9",
                    help="comma list of target load factors for the sweep")
    ap.add_argument("--calibration", type=int, default=8,
                    help="serial requests in the calibration phase "
                         "(>= the admission controller's sample floor, "
                         "so seeded moments take effect immediately)")
    ap.add_argument("--admission", choices=("gg1", "none"), default="gg1")
    ap.add_argument("--safety", type=float, default=1.3)
    ap.add_argument("--straggler",
                    choices=("none", "exp", "shift", "burst"),
                    default="exp")
    ap.add_argument("--complexity", type=float, default=10.0)
    ap.add_argument("--planes", "-m", type=int, default=2, dest="planes")
    ap.add_argument("--K", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default="BENCH_serving.json")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero if the Fig. 5 serving claim "
                         "(res-0 >= 0.99 while final < 0.5) fails")
    args = ap.parse_args(argv)

    service, samples = calibrate(args)
    mean_s = service.mean
    fig5_rate = FIG5_LOAD / mean_s
    deadline = size_deadline(args, service, fig5_rate)
    print(f"[bench-serving] calibrated full-equivalent service "
          f"{mean_s * 1e3:.1f} ms "
          f"(res-0 share ~{mean_s / args.planes**2 * 1e3:.1f} ms) -> "
          f"deadline {deadline * 1e3:.1f} ms")

    # the Fig. 5 cell: sustained Poisson load where the full resolution
    # cannot meet the deadline but res-0 still lands (first, on a fresh
    # heap — the sweep's gateway churn costs the cell tail latency)
    fig5 = serve_stream(args, rate=fig5_rate, traffic="poisson",
                        deadline=None, requests=args.requests,
                        seed=args.seed + 1, seed_service=samples)
    L = 2 * args.planes - 1
    res0 = fig5["deadline_success"]["0"]
    final = fig5["deadline_success"][str(L - 1)]
    claim = res0 >= 0.99 and final < 0.5
    print(f"[bench-serving] Fig.5 cell: res-0 success {res0:.3f}, "
          f"final-resolution success {final:.3f} "
          f"({'OK' if claim else 'CLAIM FAILED'})")

    loads = [float(x) for x in args.loads.split(",") if x]
    sweep = []
    for load in loads:
        for traffic in ("poisson", "bursty"):
            row = serve_stream(args, rate=load / mean_s, traffic=traffic,
                               deadline=deadline,
                               requests=args.sweep_requests,
                               seed=args.seed, seed_service=samples)
            row["target_load"] = load
            sweep.append(row)
            print(f"[bench-serving] load {load:.1f} {traffic:8s}: "
                  f"{row['admitted']} admitted "
                  f"({row['down_resolved']} down-resolved), "
                  f"{row['rejected']} rejected; "
                  f"res0 success {row['deadline_success']['0']:.3f}")

    out = {
        "config": {
            "mu": list(MU), "m": args.planes, "K": args.K,
            "straggler": args.straggler, "complexity": args.complexity,
            "admission": args.admission, "safety": args.safety,
            "seed": args.seed,
            "calibrated_service_ms": round(mean_s * 1e3, 3),
            "deadline_ms": round(deadline * 1e3, 3),
        },
        "sweep": sweep,
        "fig5": {**fig5, "claim_res0": res0, "claim_final": final,
                 "claim_holds": claim},
    }
    path = pathlib.Path(args.json)
    path.write_text(json.dumps(out, indent=2))
    print(f"[bench-serving] wrote {path}")
    if args.strict and not claim:
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
