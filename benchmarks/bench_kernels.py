"""Kernel micro-benchmarks (interpret-mode correctness + jnp-path timing).

On this CPU container the Pallas kernels run in interpret mode (Python), so
wall-times are NOT indicative of TPU performance; what we measure here is
(a) the jnp reference path's throughput (the XLA-compiled twin of the
kernel's math) and (b) the kernels' exactness, plus derived arithmetic
intensities that feed the roofline discussion.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import layering
from repro.kernels import ops, ref


def _time(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def bench_layered_matmul():
    rows = []
    rng = np.random.default_rng(0)
    for (m, d, K, M, N) in [(2, 7, 512, 128, 128), (2, 7, 1024, 256, 256),
                            (3, 5, 512, 128, 128)]:
        hi = 1 << (m * d - 1)
        A = jnp.asarray(rng.integers(-hi, hi, size=(K, M)), jnp.int32)
        B = jnp.asarray(rng.integers(-hi, hi, size=(K, N)), jnp.int32)
        # exactness vs oracle
        parts = np.asarray(ops.layered_matmul_partials(A, B, m=m, d=d,
                                                       interpret=True))
        pa = np.asarray(layering.decompose(A, m, d), np.int64)
        pb = np.asarray(layering.decompose(B, m, d), np.int64)
        want = np.stack([sum(pa[i].T @ pb[j] for (i, j)
                             in layering.layer_minijobs(m, l))
                         for l in range(2 * m - 1)])
        exact = bool((parts == want).all())
        # jnp twin timing
        t = _time(lambda a, b: layering.layered_matmul_jnp(a, b, m=m, d=d),
                  A, B)
        flops = 2.0 * m * m * K * M * N
        ai = flops / ((m * K * M + m * K * N) * 1 + (2 * m - 1) * M * N * 4)
        rows.append((f"layered_matmul m={m} d={d} {K}x{M}x{N}",
                     t * 1e6, f"exact={exact} AI={ai:.1f}flop/B"))
    return rows


def bench_flash_attention():
    rows = []
    rng = np.random.default_rng(1)
    for (B, S, H, dh) in [(1, 1024, 8, 64), (1, 2048, 4, 128)]:
        q = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.bfloat16)
        k = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.bfloat16)
        v = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.bfloat16)
        qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, dh)
        t = _time(lambda a: ref.flash_attention_ref(a, a, a, causal=True),
                  qf)
        flops = 4.0 * B * H * S * S * dh  # qk + pv, causal ~/2 ignored
        rows.append((f"attention_ref B={B} S={S} H={H} dh={dh}",
                     t * 1e6, f"{flops / t / 1e9:.1f} GFLOP/s (CPU jnp)"))
    return rows


def bench_ssd():
    from repro.models.ssm import ssd_scan
    rows = []
    rng = np.random.default_rng(2)
    B, S, H, P, N, chunk = 1, 2048, 8, 64, 128, 256
    x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(B, S, H)), jnp.float32)
    A = -jnp.ones((H,), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S, 1, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, S, 1, N)), jnp.float32)
    t = _time(lambda *a: ssd_scan(*a, chunk=chunk)[0], x, dt, A, Bm, Cm)
    # exactness of the fused kernel vs the jnp path
    from repro.kernels.ops import ssd_scan_fused
    yk, sk = ssd_scan_fused(x[:, :512], dt[:, :512], A, Bm[:, :512],
                            Cm[:, :512], chunk=chunk, interpret=True)
    yj, sj = ssd_scan(x[:, :512], dt[:, :512], A, Bm[:, :512], Cm[:, :512],
                      chunk=chunk)
    err = float(jnp.abs(yk - yj).max())
    rows.append((f"ssd_scan jnp B={B} S={S} H={H} chunk={chunk}",
                 t * 1e6, f"fused-kernel max err {err:.1e}"))
    return rows


def main():
    print("name,us_per_call,derived")
    for fn in (bench_layered_matmul, bench_flash_attention, bench_ssd):
        for name, us, derived in fn():
            print(f"{name},{us:.0f},{derived}")


if __name__ == "__main__":
    main()
