"""Transport benchmark: per-backend overhead + delay under real stragglers.

Measures, for each worker transport (thread / process / socket-on-
localhost; jax is CPU-smoke hardware-dependent and excluded from the
comparison by default):

1. **Dispatch + fusion overhead per round** — a no-delay, no-deadline run
   where worker compute is ~free, so wall time per round is dominated by
   the transport's submit → compute → return-path cost (pipe serialization
   and drain-thread hop for the process backend, TCP frames and receiver
   threads for the socket backend, direct calls for the thread backend),
   plus the measured per-stage dispatch cost.
2. **res-0 vs final-resolution delay** under the ``exp`` and ``shift``
   straggler regimes — the paper's layered-resolution story measured over
   real parallelism: identical master-side RNG means every backend faces
   the same injected straggler trace.
3. **The Fig. 5 qualitative claim on the process backend** — a deadline
   chosen so the *final* resolution misses on a meaningful fraction of
   jobs while res-0 still lands: early resolutions beat a deadline the
   full computation cannot, on genuinely GIL-free workers.
4. **Result-path compression (socket)** — big coded blocks over the frame
   protocol with compression off vs auto: raw-vs-wire bytes on both
   paths and the measured ratio, the JSON's compression story.
5. **The zero-copy wire path (PR 9)** — process backend with the
   shared-memory block arena off vs on (pickled pipe vs descriptor-only
   dispatch), and socket LRF1 vs LRF2 (all-pickle frames vs out-of-band
   ndarray buffers): µs/round and bytes-copied, the numbers the
   regression gate's process-roundtrip budget reads.

The socket rows spawn a
:class:`repro.runtime.transport.socket_host.LocalCluster` (real worker
host processes on localhost ports), so its numbers include genuine frame
serialization and kernel TCP hops, but not a physical network's latency.

Emits ``BENCH_transport.json``.

Run:  PYTHONPATH=src python benchmarks/bench_transport.py --jobs 120
"""

from __future__ import annotations

import argparse
import contextlib
import dataclasses
import json
import multiprocessing
import os
import pathlib
import signal
import threading
import time

import numpy as np

from repro.runtime import RuntimeConfig, delay_table, format_delay_table, \
    run_jobs
from repro.runtime.transport.socket_host import LocalCluster

MU = (385.95, 650.92, 373.40, 415.75, 373.98)   # the paper's §IV cluster
COMPARE_BACKENDS = ("thread", "process", "socket")


@contextlib.contextmanager
def _backend_env(backend: str):
    """Yield the extra RuntimeConfig kwargs a backend needs (and own the
    localhost cluster for the socket rows)."""
    if backend == "socket":
        with LocalCluster(len(MU)) as cluster:
            yield {"hosts": cluster.hosts}
    else:
        yield {}


def _run(cfg: RuntimeConfig, jobs: int) -> dict:
    t0 = time.perf_counter()
    result, _ = run_jobs(cfg, jobs, K=64, M=8, N=8)
    wall = time.perf_counter() - t0
    s = result.stage_seconds or {}
    rounds = max(result.stage_rounds, 1)
    rows = delay_table(result)
    return {
        "backend": result.backend,
        "jobs": jobs,
        "wall_seconds": round(wall, 3),
        "rounds": result.stage_rounds,
        "dispatch_us_per_round": round(s.get("dispatch", 0.0) / rounds * 1e6,
                                       2),
        "wait_us_per_round": round(s.get("wait", 0.0) / rounds * 1e6, 2),
        "master_overhead_us_per_round": round(
            result.per_round_overhead() * 1e6, 2),
        "stale_results": int(result.stale_results),
        "terminated": int(result.terminated.sum()),
        "success_rate": [round(float(x), 4) for x in result.success_rate()],
        "res0_mean_delay": rows[0]["mean_delay"],
        "final_mean_delay": rows[-1]["mean_delay"],
        "delay_per_resolution": rows,
        "worker_utilization": [round(float(u), 4)
                               for u in result.utilization],
        "transport_stats": result.transport_stats,
    }


def bench_overhead(jobs: int) -> list[dict]:
    """No injected delay: per-round wall cost IS the transport overhead."""
    out = []
    for backend in COMPARE_BACKENDS:
        with _backend_env(backend) as extra:
            cfg = RuntimeConfig(mu=MU, arrival_rate=1000.0, complexity=0.2,
                                straggler="none", backend=backend, seed=0,
                                **extra)
            r = _run(cfg, jobs)
        # with zero injected delay, (dispatch + wait) per round is the
        # submit -> compute -> fuse round-trip latency of the transport
        r["roundtrip_us_per_round"] = round(
            r["dispatch_us_per_round"] + r["wait_us_per_round"], 2)
        out.append(r)
        print(f"[overhead] {backend:>8}: dispatch "
              f"{r['dispatch_us_per_round']:>8.1f} us/round, roundtrip "
              f"{r['roundtrip_us_per_round']:>8.1f} us/round, wall "
              f"{r['wall_seconds']:.2f} s")
    return out


def bench_regimes(jobs: int) -> list[dict]:
    """res-0 / final delay per backend, exp and shift regimes."""
    regimes = {
        "exp": dict(arrival_rate=12.0, complexity=10.0, straggler="exp"),
        "shift": dict(arrival_rate=12.0, complexity=10.0, straggler="shift",
                      stall_workers=(4,), shift_at=1.0, stall_seconds=2.0,
                      deadline=0.060),
    }
    out = []
    for regime, kw in regimes.items():
        for backend in COMPARE_BACKENDS:
            with _backend_env(backend) as extra:
                cfg = RuntimeConfig(mu=MU, backend=backend, seed=3, **kw,
                                    **extra)
                r = _run(cfg, jobs)
            r["regime"] = regime
            out.append(r)
            print(f"[{regime:>5}] {backend:>8}: res0 "
                  f"{r['res0_mean_delay'] * 1e3:7.2f} ms, final "
                  f"{r['final_mean_delay'] * 1e3:7.2f} ms, success "
                  f"{r['success_rate']}")
    return out


def bench_compression(jobs: int) -> list[dict]:
    """Socket frame compression on big blocks: off vs auto.

    Uses M = N = 96 so each task result is a 48x48 float64 block (~18 KB
    pickled — comfortably above the auto threshold) and each dispatched
    codeword slice is proportionally bigger: the regime the ROADMAP's
    "result-path compression for big blocks" follow-on names.  Reports
    raw-vs-wire bytes both ways and the result-path ratio.
    """
    out = []
    with LocalCluster(len(MU)) as cluster:
        for compress in ("none", "auto"):
            cfg = RuntimeConfig(mu=MU, arrival_rate=1000.0, complexity=0.2,
                                straggler="none", backend="socket",
                                hosts=cluster.hosts, compress=compress,
                                seed=0)
            t0 = time.perf_counter()
            result, _ = run_jobs(cfg, jobs, K=64, M=96, N=96)
            wall = time.perf_counter() - t0
            ws = result.transport_stats or {}
            row = {
                "compress": compress,
                "jobs": jobs,
                "wall_seconds": round(wall, 3),
                "res0_mean_delay": delay_table(result)[0]["mean_delay"],
                **ws,
            }
            out.append(row)
            print(f"[compress] {compress:>5}: result path "
                  f"{ws.get('result_raw_bytes', 0) / 1e6:7.2f} MB raw -> "
                  f"{ws.get('result_wire_bytes', 0) / 1e6:7.2f} MB wire "
                  f"(ratio {ws.get('compression_ratio', 1.0):.2f}x), "
                  f"wall {wall:.2f} s")
    return out


def bench_wire_path(jobs: int) -> list[dict]:
    """Zero-copy vs serialized wire paths, µs/round and bytes copied.

    Same no-delay regime as :func:`bench_overhead`, so per-round wall
    cost is the transport round trip.  For the process pair the only
    difference is ``shm`` (pickled pipe vs shared-memory arena); for the
    socket pair it is ``frame_proto`` (LRF1 pickles everything in-band,
    LRF2 ships ndarray buffers out-of-band), measured over one
    LocalCluster per variant so each pair negotiates from scratch.
    """
    out = []
    for mode in ("off", "on"):
        cfg = RuntimeConfig(mu=MU, arrival_rate=1000.0, complexity=0.2,
                            straggler="none", backend="process", shm=mode,
                            seed=0)
        r = _run(cfg, jobs)
        r["variant"] = f"process-shm-{mode}"
        r["roundtrip_us_per_round"] = round(
            r["dispatch_us_per_round"] + r["wait_us_per_round"], 2)
        ws = r["transport_stats"] or {}
        print(f"[wire] {r['variant']:>15}: dispatch "
              f"{r['dispatch_us_per_round']:>8.1f} us/round, roundtrip "
              f"{r['roundtrip_us_per_round']:>8.1f} us/round, "
              f"arena/pickle rounds {ws.get('arena_rounds', 0)}/"
              f"{ws.get('pickle_rounds', 0)}")
        out.append(r)
    for proto in (1, 2):
        with LocalCluster(len(MU)) as cluster:
            cfg = RuntimeConfig(mu=MU, arrival_rate=1000.0, complexity=0.2,
                                straggler="none", backend="socket",
                                hosts=cluster.hosts, frame_proto=proto,
                                seed=0)
            r = _run(cfg, jobs)
        r["variant"] = f"socket-lrf{proto}"
        r["roundtrip_us_per_round"] = round(
            r["dispatch_us_per_round"] + r["wait_us_per_round"], 2)
        ws = r["transport_stats"] or {}
        copied = ws.get("dispatch_copied_bytes", 0)
        oob = ws.get("dispatch_oob_bytes", 0)
        print(f"[wire] {r['variant']:>15}: dispatch "
              f"{r['dispatch_us_per_round']:>8.1f} us/round, roundtrip "
              f"{r['roundtrip_us_per_round']:>8.1f} us/round, copied "
              f"{copied / 1e6:.2f} MB, out-of-band {oob / 1e6:.2f} MB")
        out.append(r)
    return out


def bench_hierarchical(jobs: int) -> list[dict]:
    """Sub-task-granular coding vs the purge-everything baseline.

    Same process fleet, same seed, same injected straggler trace, equal
    aggregate redundancy ω: the polynomial family discards every
    un-fused task at round end, while the hierarchical family dispatches
    groups of ``levels`` MSB-first rounds and *banks* deep-level
    sub-task results while the master still waits on the frontier level
    (the salvage ledger in ``transport_stats``).  Two afflicted workers
    make the outage bind — at equal budget the MSB-heavy per-level
    rates buy res-0 extra parity, so res-0 mean delay improves while
    salvaged sub-tasks keep the deeper levels fed.
    """
    regimes = {
        "stall": dict(straggler="stall", stall_workers=(3, 4),
                      stall_seconds=2.0),
        "burst": dict(straggler="burst", stall_workers=(3, 4),
                      burst_period=1.0, burst_len=0.4),
    }
    out = []
    for regime, kw in regimes.items():
        pair = {}
        for family in ("polynomial", "hierarchical"):
            extra = {"levels": 2} if family == "hierarchical" else {}
            cfg = RuntimeConfig(mu=MU, backend="process", shm="off",
                                omega=1.75, arrival_rate=12.0,
                                complexity=10.0, deadline=0.060, seed=3,
                                code_family=family, **kw, **extra)
            r = _run(cfg, jobs)
            r["regime"] = regime
            r["code_family"] = family
            out.append(r)
            pair[family] = r
        p, h = pair["polynomial"], pair["hierarchical"]
        salv = (h["transport_stats"] or {}).get("salvaged_subtasks", 0)
        print(f"[hier] {regime:>5}: res0 "
              f"{p['res0_mean_delay'] * 1e3:6.2f} -> "
              f"{h['res0_mean_delay'] * 1e3:6.2f} ms, res0 success "
              f"{p['success_rate'][0]:.3f} -> {h['success_rate'][0]:.3f}, "
              f"salvaged subtasks {salv}")
    return out


def bench_deadline_race(jobs: int) -> dict:
    """Fig. 5 qualitative claim, process backend: res-0 beats a deadline
    the final resolution misses."""
    cfg = RuntimeConfig(mu=MU, arrival_rate=14.0, complexity=10.0,
                        deadline=0.035, straggler="exp", backend="process",
                        seed=1)
    r = _run(cfg, jobs)
    r["scenario"] = "deadline-race"
    res0_ok = r["success_rate"][0]
    final_ok = r["success_rate"][-1]
    r["fig5_claim_holds"] = bool(res0_ok >= 0.95 and final_ok < 1.0)
    print(f"[deadline-race] process: res0 success {res0_ok:.3f}, final "
          f"success {final_ok:.3f}, claim holds: {r['fig5_claim_holds']}")
    print(format_delay_table(r["delay_per_resolution"]))
    return r


def bench_chaos(jobs: int) -> list[dict]:
    """Worker-loss regime (docs/fault-tolerance.md): SIGKILL one process
    worker mid-run, once per fault policy.

    ``degrade`` must absorb the loss — quarantine, geometry refit,
    re-dispatch — and finish the stream; ``fail-fast`` must fail
    promptly with the typed error.  Both sides of the fault-policy
    contract, measured: deadline success under loss for the former,
    time-to-failure for the latter.
    """
    out = []
    for policy in ("degrade", "fail-fast"):
        cfg = RuntimeConfig(mu=MU, arrival_rate=12.0, complexity=8.0,
                            deadline=0.100, straggler="none",
                            backend="process", fault_policy=policy, seed=5)
        holder: dict = {}

        def drive(cfg=cfg, holder=holder):
            t0 = time.perf_counter()
            try:
                holder["result"], _ = run_jobs(cfg, jobs, K=64, M=8, N=8)
            except RuntimeError as e:
                holder["error"] = type(e).__name__
            holder["wall"] = time.perf_counter() - t0

        t = threading.Thread(target=drive, daemon=True)
        t.start()
        spawn_deadline = time.monotonic() + 20.0
        procs: dict = {}
        while time.monotonic() < spawn_deadline and len(procs) < len(MU):
            procs = {p.name: p for p in multiprocessing.active_children()
                     if p.name.startswith("runtime-proc-worker-")}
            time.sleep(0.02)
        time.sleep(0.5)
        victim = procs.get("runtime-proc-worker-1")
        if victim is not None and victim.pid:
            os.kill(victim.pid, signal.SIGKILL)
        t.join(120.0)
        row = {"policy": policy, "jobs": jobs, "scenario": "sigkill-1",
               "wall_seconds": round(holder.get("wall", float("nan")), 3)}
        if "result" in holder:
            res = holder["result"]
            row.update(
                outcome="completed",
                workers_lost=int(res.workers_lost),
                degraded_jobs=int(res.degraded.sum()
                                  if res.degraded is not None else 0),
                success_rate=[round(float(x), 4)
                              for x in res.success_rate()],
                fault_events=[e["kind"] for e in (res.fault_log or [])])
        else:
            row["outcome"] = holder.get("error", "hung")
        out.append(row)
        print(f"[chaos] {policy:>9}: {row['outcome']} in "
              f"{row['wall_seconds']:.2f} s"
              + (f", lost {row['workers_lost']}, degraded "
                 f"{row['degraded_jobs']}, res0 success "
                 f"{row['success_rate'][0]:.3f}"
                 if row["outcome"] == "completed" else ""))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--jobs", type=int, default=120)
    ap.add_argument("--out", default="BENCH_transport.json")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero if the fig5 qualitative claim "
                         "fails (a probabilistic wall-clock property: use "
                         "locally for acceptance runs, not on shared CI "
                         "runners where a noisy neighbor can flip it)")
    args = ap.parse_args(argv)

    report = {
        "bench": "transport",
        "jobs": args.jobs,
        "mu": list(MU),
        "overhead": bench_overhead(args.jobs),
        "wire_path": bench_wire_path(args.jobs),
        "regimes": bench_regimes(args.jobs),
        "deadline_race": bench_deadline_race(args.jobs),
        "hierarchical": bench_hierarchical(max(40, args.jobs // 2)),
        "chaos": bench_chaos(max(20, args.jobs // 2)),
        "compression": bench_compression(max(10, args.jobs // 4)),
    }
    path = pathlib.Path(args.out)
    path.write_text(json.dumps(report, indent=2))
    print(f"\nwrote {path}")
    if not report["deadline_race"]["fig5_claim_holds"]:
        print("WARNING: fig5 qualitative claim did not hold on this host "
              "(res-0 under deadline while final misses); inspect the "
              "delay table above")
        return 1 if args.strict else 0
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
