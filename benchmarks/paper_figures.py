"""Reproduction of the paper's §IV figures (the faithful-baseline evidence).

Each function mirrors one figure; outputs go to results/ as CSV + a printed
summary with the paper's qualitative claims checked programmatically.
Paper parameters: P=5 workers with mu = [385.95, 650.92, 373.40, 415.75,
373.98], Poisson arrivals lambda=0.01, k=1000 tasks/matmul, task complexity
50 (12.5 layered, m=2 -> L=3 resolution layers).
"""

from __future__ import annotations

import csv
import os

import numpy as np

from repro.core import queueing, simulator

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def _write_csv(name: str, header, rows):
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, name)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return path


def fig2a_delay_vs_redundancy(num_jobs: int = 4000, seed: int = 0):
    """Average delay vs redundancy ratio + theory lower bounds (Fig 2a)."""
    omegas = [1.0, 1.006, 1.012, 1.018, 1.03, 1.06, 1.1, 1.15, 1.2]
    rows = []
    checks = []
    for om in omegas:
        cfg = simulator.SystemConfig(omega=om)
        lay = simulator.simulate(cfg, num_jobs, layered=True, seed=seed)
        unlay = simulator.simulate(cfg, num_jobs, layered=False, seed=seed)
        d = lay.mean_delay()
        dn = unlay.mean_delay()[0]
        bounds = simulator.theory_bounds(cfg, lay.service_moments(),
                                         layered=True)
        rows.append([om, *d, dn, *bounds])
        checks.append((om, d, bounds, dn))
    path = _write_csv("fig2a_delay_vs_redundancy.csv",
                      ["omega", "D_l0", "D_l1", "D_l2", "D_nolayer",
                       "bound_l0", "bound_l1", "bound_l2"], rows)

    # paper claims: (i) layer delays ordered; (ii) final ~= no-layering;
    # (iii) bounds tight at ~6% redundancy.
    om6 = next(c for c in checks if abs(c[0] - 1.06) < 1e-9)
    tightness = float(np.max((om6[1] - om6[2]) / om6[2]))
    ordered = bool(np.all(np.diff(om6[1]) > 0))
    final_vs_nolayer = abs(om6[1][-1] - om6[3]) / om6[3]
    print(f"fig2a: {path}")
    print(f"  claim[layer order D(0)<D(1)<D(2)]: {ordered}")
    print(f"  claim[final==no-layering within 5%]: "
          f"{final_vs_nolayer:.3f} ({final_vs_nolayer < 0.05})")
    print(f"  claim[bounds tight at omega=1.06]: max gap "
          f"{tightness*100:.1f}% ({tightness < 0.08})")
    return {"tight_at_1.06": tightness, "ordered": ordered,
            "final_vs_nolayer": final_vs_nolayer}


def fig2b_job_realizations(num_jobs: int = 100, seed: int = 1):
    """Per-job delay realizations for the first 100 jobs (Fig 2b)."""
    cfg = simulator.SystemConfig(omega=1.06)
    lay = simulator.simulate(cfg, num_jobs, layered=True, seed=seed)
    unlay = simulator.simulate(cfg, num_jobs, layered=False, seed=seed)
    d = lay.delay
    rows = [[j, *d[j], unlay.delay[j, 0]] for j in range(num_jobs)]
    path = _write_csv("fig2b_realizations.csv",
                      ["job", "D_l0", "D_l1", "D_l2", "D_nolayer"], rows)
    frac_ordered = float(np.mean((d[:, 0] < d[:, 1]) & (d[:, 1] < d[:, 2])))
    print(f"fig2b: {path}")
    print(f"  claim[every job sees layered early results]: "
          f"{frac_ordered*100:.0f}% of jobs strictly ordered")
    return {"frac_ordered": frac_ordered}


def fig3a_delay_distribution(num_jobs: int = 1000, seed: int = 2):
    """Empirical delay distributions per resolution, omega=1.018 (Fig 3a)."""
    cfg = simulator.SystemConfig(omega=1.018)
    lay = simulator.simulate(cfg, num_jobs, layered=True, seed=seed)
    d = lay.delay
    qs = [5, 25, 50, 75, 95]
    rows = []
    for l in range(d.shape[1]):
        pct = np.percentile(d[:, l], qs)
        rows.append([l, d[:, l].mean(), d[:, l].std(), *pct])
    path = _write_csv("fig3a_delay_distribution.csv",
                      ["layer", "mean", "std", "p5", "p25", "p50", "p75",
                       "p95"], rows)
    # higher layers have wider distributions (claim)
    stds = [r[2] for r in rows]
    widening = all(a <= b * 1.05 for a, b in zip(stds, stds[1:]))
    print(f"fig3a: {path}")
    print(f"  claim[higher layers have wider distributions]: {widening} "
          f"(stds: {[f'{s:.2f}' for s in stds]})")
    return {"stds": stds, "widening": widening}


def fig3b_success_rate(num_jobs: int = 1000, seed: int = 3):
    """Success rate vs deadline, omega=1.018 (Fig 3b)."""
    cfg = simulator.SystemConfig(omega=1.018)
    deadlines = [5.0, 7.5, 10.0, 12.5, 15.0, 20.0, 25.0, 30.0, 40.0]
    rows = []
    at10 = None
    for dl in deadlines:
        lay = simulator.simulate(cfg, num_jobs, layered=True, deadline=dl,
                                 seed=seed)
        unlay = simulator.simulate(cfg, num_jobs, layered=False, deadline=dl,
                                   seed=seed)
        sr = lay.success_rate()
        srn = unlay.success_rate()[0]
        rows.append([dl, *sr, srn])
        if dl == 10.0:
            at10 = (sr, srn)
    path = _write_csv("fig3b_success_rate.csv",
                      ["deadline", "sr_l0", "sr_l1", "sr_l2", "sr_nolayer"],
                      rows)
    print(f"fig3b: {path}")
    print(f"  claim[success(l0)=1 at deadline 10 while others lower]: "
          f"l0={at10[0][0]:.3f}, l2={at10[0][2]:.3f}, "
          f"no-layer={at10[1]:.3f}")
    return {"sr_at_10": (float(at10[0][0]), float(at10[0][2]),
                         float(at10[1]))}


def run_all(fast: bool = False):
    n = 800 if fast else 4000
    out = {}
    out["fig2a"] = fig2a_delay_vs_redundancy(num_jobs=n)
    out["fig2b"] = fig2b_job_realizations()
    out["fig3a"] = fig3a_delay_distribution(num_jobs=min(n, 1000))
    out["fig3b"] = fig3b_success_rate(num_jobs=min(n, 1000))
    return out


if __name__ == "__main__":
    run_all()
