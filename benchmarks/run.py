"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

Sections:
  1. Paper figures 2a/2b/3a/3b (the faithful reproduction; claim checks)
  2. Coded-matmul throughput / erasure sweep
  3. Kernel micro-benches (interpret-mode exactness + jnp twin timing)
  4. Roofline table from the dry-run artifacts (if results/dryrun exists)

One CSV-ish block per paper table/figure, per the harness contract.
"""

from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="smaller job counts for CI-speed runs")
    ap.add_argument("--skip", default="",
                    help="comma list: figures,coded,kernels,roofline")
    args = ap.parse_args()
    skip = set(args.skip.split(",")) if args.skip else set()

    if "figures" not in skip:
        print("#" * 72)
        print("# paper figures (benchmarks/paper_figures.py)")
        from benchmarks import paper_figures
        paper_figures.run_all(fast=args.fast)

    if "coded" not in skip:
        print("#" * 72)
        print("# coded matmul (benchmarks/bench_coded_matmul.py)")
        from benchmarks import bench_coded_matmul
        bench_coded_matmul.main()

    if "kernels" not in skip:
        print("#" * 72)
        print("# kernels (benchmarks/bench_kernels.py)")
        from benchmarks import bench_kernels
        bench_kernels.main()

    if "roofline" not in skip:
        print("#" * 72)
        print("# roofline (benchmarks/roofline_table.py; source: dry-run)")
        from benchmarks import roofline_table
        if os.path.isdir(roofline_table.RESULTS):
            try:
                print(roofline_table.table("single"))
                s = roofline_table.summary("single")
                w = s["worst_fraction"]
                print(f"\ncells: {s['num_cells']}; worst roofline fraction: "
                      f"{w['arch']} x {w['shape']} "
                      f"({w.get('roofline_fraction', 0):.4f})")
            except Exception as e:  # empty dir mid-sweep etc.
                print(f"(roofline table unavailable: {e})")
        else:
            print("(no results/dryrun — run python -m repro.launch.dryrun)")


if __name__ == "__main__":
    main()
