"""CI gate: fail if the runtime bench regressed vs the committed baseline.

Compares a fresh ``BENCH_runtime.json`` against
``benchmarks/BENCH_runtime.baseline.json`` scenario by scenario and exits
non-zero if any scenario's mean resolution-0 delay regressed by more than
``--max-regress`` (default 25%).  Resolution 0 is the paper's headline —
it carries the master's per-round overhead almost undiluted, so a
pipeline/decode-plan regression shows up here first.

The committed baseline encodes absolute wall-clock delays, so it is only
comparable across machines of the same class: regenerate it
(``bench_runtime.py --jobs 200 --out benchmarks/BENCH_runtime.baseline.json``)
whenever the CI runner class changes, and treat a uniform shift across
all three scenarios as a machine change, not a code regression.

When a fresh transport bench artifact is available (``--transport-new``,
skipped with a note when absent so the gate still runs standalone), the
zero-copy wire path is gated too: the process backend's shm-on roundtrip
must not regress vs the committed ``benchmarks/BENCH_transport.json``
beyond the same budget, and the arena must actually have carried the
rounds — a silent fall-back to the pickled path is a perf regression by
another name.

Run:  PYTHONPATH=src python benchmarks/check_runtime_regression.py \
          --new BENCH_runtime.json [--transport-new BENCH_transport.json]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

BASELINE = pathlib.Path(__file__).parent / "BENCH_runtime.baseline.json"
TRANSPORT_BASELINE = pathlib.Path(__file__).parent / "BENCH_transport.json"


def res0_mean_delay(scenario: dict) -> float:
    rows = scenario["measured_delay_per_resolution"]
    row = next(r for r in rows if r["resolution"] == 0)
    return float(row["mean_delay"])


def compare(baseline: dict, new: dict, max_regress: float) -> list[str]:
    """Human-readable failures; empty when everything is within budget."""
    base_by_name = {s["name"]: s for s in baseline["scenarios"]}
    failures = []
    for scenario in new["scenarios"]:
        name = scenario["name"]
        base = base_by_name.get(name)
        if base is None:
            print(f"[check] {name}: no baseline scenario, skipping")
            continue
        b, n = res0_mean_delay(base), res0_mean_delay(scenario)
        ratio = n / b if b > 0 else float("inf")
        status = "OK" if ratio <= 1.0 + max_regress else "REGRESSED"
        print(f"[check] {name}: res0 mean delay {b * 1e3:.2f} ms -> "
              f"{n * 1e3:.2f} ms ({ratio:.2f}x)  {status}")
        if ratio > 1.0 + max_regress:
            failures.append(
                f"{name}: res0 mean delay {ratio:.2f}x baseline "
                f"(budget {1.0 + max_regress:.2f}x)")
    return failures


def check_tracing_overhead(new: dict, max_overhead_us: float) -> list[str]:
    """Gate the bench's tracing-overhead row.

    Enabled tracing must stay under ``max_overhead_us`` per round;
    disabled tracing has no separate budget here because the disabled
    path *is* the engine the three scenario gates above already bound —
    any disabled-path cost shows up as a res-0 regression.  Old artifacts
    without the section are skipped with a note, not failed.
    """
    row = new.get("tracing_overhead")
    if row is None:
        print("[check] tracing_overhead: section absent (old bench "
              "artifact), skipping")
        return []
    delta = float(row["overhead_us_per_round"])
    status = "OK" if delta <= max_overhead_us else "REGRESSED"
    print(f"[check] tracing_overhead: disabled "
          f"{row['per_round_us_disabled']:.1f} us/round, enabled "
          f"{row['per_round_us_enabled']:.1f} us/round, delta "
          f"{delta:+.1f} us/round (budget {max_overhead_us:.0f})  {status}")
    if delta > max_overhead_us:
        return [f"tracing overhead {delta:+.1f} us/round exceeds "
                f"{max_overhead_us:.0f} us/round budget"]
    return []


def _wire_variant(report: dict, variant: str) -> dict | None:
    return next((r for r in report.get("wire_path", [])
                 if r.get("variant") == variant), None)


def check_process_roundtrip(new_path: str, baseline_path: str,
                            max_regress: float) -> list[str]:
    """Gate the shm-on process roundtrip against the committed artifact.

    Skips (with a note) when either artifact or its ``wire_path``
    section is absent — the transport bench runs on a separate CI step
    and older artifacts predate the section.
    """
    new_file = pathlib.Path(new_path)
    if not new_file.exists():
        print(f"[check] wire_path: {new_path} absent (transport bench "
              f"not run), skipping")
        return []
    new = json.loads(new_file.read_text())
    base = json.loads(pathlib.Path(baseline_path).read_text())
    n = _wire_variant(new, "process-shm-on")
    b = _wire_variant(base, "process-shm-on")
    if n is None or b is None:
        print("[check] wire_path: process-shm-on row absent (pre-arena "
              "artifact), skipping")
        return []
    failures = []
    b_us = float(b["roundtrip_us_per_round"])
    n_us = float(n["roundtrip_us_per_round"])
    ratio = n_us / b_us if b_us > 0 else float("inf")
    status = "OK" if ratio <= 1.0 + max_regress else "REGRESSED"
    print(f"[check] wire_path process-shm-on: roundtrip {b_us:.1f} -> "
          f"{n_us:.1f} us/round ({ratio:.2f}x)  {status}")
    if ratio > 1.0 + max_regress:
        failures.append(
            f"process shm roundtrip {ratio:.2f}x baseline "
            f"(budget {1.0 + max_regress:.2f}x)")
    ws = n.get("transport_stats") or {}
    arena, pickled = ws.get("arena_rounds", 0), ws.get("pickle_rounds", 0)
    if not arena or pickled > arena:
        failures.append(
            f"shm-on run was not arena-carried (arena_rounds={arena}, "
            f"pickle_rounds={pickled}) — ring sizing or attach regressed")
    else:
        print(f"[check] wire_path process-shm-on: arena carried "
              f"{arena}/{arena + pickled} dispatches  OK")
    return failures


def check_hierarchical_salvage(new_path: str) -> list[str]:
    """Gate the hierarchical family's salvage claim.

    The bench's ``hierarchical`` section runs the sub-task-granular
    family against the purge-everything polynomial baseline at equal ω.
    Under the ``stall`` regime the salvage ledger must be nonzero —
    deep-level sub-task results banked while the master waited on the
    frontier.  A zero ledger means grouped dispatch silently degraded to
    task-granular behavior (frontier never trailed the arrivals), which
    is a correctness-of-mechanism regression even when delays look fine.
    Skips with a note when the artifact or section is absent.
    """
    new_file = pathlib.Path(new_path)
    if not new_file.exists():
        print(f"[check] hierarchical: {new_path} absent (transport bench "
              f"not run), skipping")
        return []
    rows = json.loads(new_file.read_text()).get("hierarchical")
    if not rows:
        print("[check] hierarchical: section absent (pre-hierarchical "
              "artifact), skipping")
        return []
    failures = []
    for regime in ("stall", "burst"):
        row = next((r for r in rows
                    if r.get("regime") == regime
                    and r.get("code_family") == "hierarchical"), None)
        if row is None:
            failures.append(f"hierarchical {regime} row missing from "
                            f"bench artifact")
            continue
        ws = row.get("transport_stats") or {}
        salvaged = int(ws.get("salvaged_subtasks", 0))
        accepted = int(ws.get("subtask_results", 0))
        if regime == "stall" and salvaged <= 0:
            failures.append(
                f"hierarchical {regime}: salvaged_subtasks={salvaged} "
                f"(must be > 0 — grouped dispatch banked nothing)")
        else:
            print(f"[check] hierarchical {regime}: salvaged "
                  f"{salvaged}/{accepted} accepted sub-task results  OK")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--new", default="BENCH_runtime.json",
                    help="fresh bench artifact to validate")
    ap.add_argument("--baseline", default=str(BASELINE))
    ap.add_argument("--max-regress", type=float, default=0.25,
                    help="allowed fractional regression (0.25 = +25%%)")
    ap.add_argument("--max-trace-overhead-us", type=float, default=50.0,
                    help="budget for enabled-tracing cost per round "
                         "(microseconds)")
    ap.add_argument("--transport-new", default="BENCH_transport.json",
                    help="fresh transport bench artifact (skipped with a "
                         "note when absent)")
    ap.add_argument("--transport-baseline", default=str(TRANSPORT_BASELINE))
    args = ap.parse_args(argv)

    baseline = json.loads(pathlib.Path(args.baseline).read_text())
    new = json.loads(pathlib.Path(args.new).read_text())
    failures = compare(baseline, new, args.max_regress)
    failures += check_tracing_overhead(new, args.max_trace_overhead_us)
    failures += check_process_roundtrip(args.transport_new,
                                        args.transport_baseline,
                                        args.max_regress)
    failures += check_hierarchical_salvage(args.transport_new)
    if failures:
        print("[check] FAIL:\n  " + "\n  ".join(failures), file=sys.stderr)
        return 1
    print("[check] all scenarios within regression budget")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
