"""Render the baseline-vs-optimized roofline comparison (EXPERIMENTS §Perf)."""

from __future__ import annotations

import glob
import json
import os

HERE = os.path.dirname(__file__)
BASE = os.path.join(HERE, "..", "results", "dryrun")
PERF = os.path.join(HERE, "..", "results", "perf")


def _load(path):
    with open(path) as f:
        return json.load(f)


def _step(r):
    return max(r["compute_s"], r["memory_s"], r["collective_s"])


def table() -> str:
    rows = []
    for fn in sorted(glob.glob(os.path.join(PERF, "*__opt.json"))):
        opt = _load(fn)
        if opt.get("status") != "ok":
            continue
        base_fn = os.path.join(
            BASE, f"{opt['arch']}__{opt['shape']}__{opt['mesh']}.json")
        if not os.path.exists(base_fn):
            continue
        base = _load(base_fn)
        sb, so = _step(base), _step(opt)
        speedup = sb / so if so else float("inf")
        fb = base.get("roofline_fraction", 0.0)
        fo = opt.get("roofline_fraction", 0.0)
        rows.append((speedup, (
            f"| {opt['arch']} | {opt['shape']} "
            f"| {sb:.4g} ({base['bound'][:4]}) | {so:.4g} ({opt['bound'][:4]}) "
            f"| **{speedup:.1f}x** | {fb:.3f} → {fo:.3f} "
            f"| {opt.get('profile','')}"
            f"{'+int8kv' if opt.get('tag','').find('opt')>=0 and opt['kind']=='decode' else ''}"
            f"{'+mg1024' if 'moe' in opt['arch'] or 'llama4' in opt['arch'] else ''} |")))
    rows.sort(key=lambda r: -r[0])
    lines = ["| arch | shape | baseline step_s (bound) | optimized step_s "
             "(bound) | speedup | roofline frac | config |",
             "|---|---|---|---|---|---|---|"]
    lines += [r[1] for r in rows]
    return "\n".join(lines)


if __name__ == "__main__":
    print(table())
