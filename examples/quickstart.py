"""Quickstart: the paper end-to-end on one machine in ~a minute.

1. Layered coded matmul: digit-decompose two matrices, polynomial-encode the
   mini-jobs, lose a third of the workers, and still reconstruct — watching
   the result sharpen resolution by resolution (paper §III).
2. The same layering fused into a TPU Pallas kernel (interpret mode here).
3. The queueing simulation headline (paper §IV): at a deadline where the
   full result almost never arrives, the first resolution *always* does.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import simulator
from repro.core.layered_matmul import LayeredCodedMatmul
from repro.kernels import ops


def part1_layered_coded_matmul():
    print("=" * 72)
    print("1) Layered + coded matmul with erasures (paper §III)")
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.normal(size=(256, 32)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(256, 24)), jnp.float32)

    pipe = LayeredCodedMatmul(m=2, d=8, n1=2, n2=2, omega=2.0)
    # 8 coded tasks; any 4 suffice. Erase 4 of them (stragglers).
    res, _ = pipe.run(A, B, erasures=[1, 3, 6, 7])
    exact = np.asarray(A.T @ B)
    print(f"   coded tasks: {pipe.code.num_tasks}, needed: {pipe.code.k}, "
          f"erased: 4 (half the cluster)")
    for l in range(res.shape[0]):
        err = np.abs(res[l] - exact).max() / np.abs(exact).max()
        print(f"   resolution {l}: relative error {err:.5f}")
    assert np.abs(res[-1] - exact).max() / np.abs(exact).max() < 1e-2


def part2_pallas_kernel():
    print("=" * 72)
    print("2) The same layering as one fused MXU kernel (Pallas, interpret)")
    rng = np.random.default_rng(1)
    A = jnp.asarray(rng.integers(-8000, 8000, size=(512, 128)), jnp.int32)
    B = jnp.asarray(rng.integers(-8000, 8000, size=(512, 128)), jnp.int32)
    res = ops.layered_matmul(A, B, m=2, d=7, interpret=True)
    exact = np.asarray(A, np.int64).T @ np.asarray(B, np.int64)
    for l in range(res.shape[0]):
        err = np.abs(np.asarray(res[l]) - exact).max()
        print(f"   resolution {l}: max abs error {err:.3e}")
    parts = ops.layered_matmul_partials(A, B, m=2, d=7, interpret=True)
    scales = np.asarray([1 << ((2 * 2 - 2 - l) * 7) for l in range(3)],
                        np.int64)
    recon = (np.asarray(parts, np.int64)
             * scales[:, None, None]).cumsum(0)[-1]
    print(f"   int64 host fusion bit-exact: {np.array_equal(recon, exact)}")


def part3_deadline_simulation():
    print("=" * 72)
    print("3) Deadline success (paper Fig 3b): P=5 heterogeneous workers")
    cfg = simulator.SystemConfig(omega=1.018)
    lay = simulator.simulate(cfg, 500, layered=True, deadline=10.0, seed=0)
    unlay = simulator.simulate(cfg, 500, layered=False, deadline=10.0,
                               seed=0)
    sr = lay.success_rate()
    print(f"   deadline = 10: success rate per resolution: "
          f"l0={sr[0]:.3f}  l1={sr[1]:.3f}  l2={sr[2]:.3f}")
    print(f"   without layering: {unlay.success_rate()[0]:.3f}")
    print(f"   -> a terminated job still ships resolution 0 "
          f"({100 * sr[0]:.0f}% of jobs) instead of nothing.")


if __name__ == "__main__":
    part1_layered_coded_matmul()
    part2_pallas_kernel()
    part3_deadline_simulation()
    print("=" * 72)
    print("quickstart OK")
