"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps.

Uses the full production stack — config, sharded step builder, synthetic
bigram data pipeline, AdamW, async checkpointing — on this machine's
devices.  The bigram chain has entropy ln(branching) = ln(8) ~= 2.08 nats,
so the loss falling from ~ln(V) ~= 10.4 toward ~2 demonstrates real
learning, not just a smoke test.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
(~15 min on this container's single CPU core at the default size; use
--d-model 256 --layers 4 for a 2-minute version.)
"""

import argparse

from repro.configs.base import AttentionConfig, ModelConfig, TrainConfig
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--layers", type=int, default=12)
    ap.add_argument("--d-model", type=int, default=768)
    ap.add_argument("--ckpt-dir", default="results/ckpt_train_lm")
    args = ap.parse_args()

    heads = max(args.d_model // 64, 2)
    cfg = ModelConfig(
        name="train-lm-100m", family="dense", num_layers=args.layers,
        d_model=args.d_model, d_ff=4 * args.d_model, vocab_size=32_768,
        attention=AttentionConfig(num_heads=heads,
                                  num_kv_heads=max(heads // 4, 1),
                                  head_dim=64),
        tie_embeddings=True, compute_dtype="float32", remat_policy="none")
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=30,
                       total_steps=args.steps)
    out = train_loop(cfg, tcfg, batch=args.batch, seq=args.seq,
                     steps=args.steps, ckpt_dir=args.ckpt_dir,
                     ckpt_every=100, log_every=10)
    first, last = out["losses"][0][1], out["losses"][-1][1]
    print(f"\nloss: {first:.3f} -> {last:.3f} "
          f"(chain entropy floor ~2.08, vocab ceiling ~10.4)")
    assert last < first - 1.0, "model failed to learn the bigram chain"
    print("train_lm OK")


if __name__ == "__main__":
    main()
