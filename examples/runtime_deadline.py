"""The measured runtime under stragglers and deadlines, end to end.

1. A worker pool with one *stalled* worker and a deadline the final
   resolution misses: every job still releases a decode-verified lower
   resolution — the paper's headline, on a real execution instead of a
   sampled one.
2. The same cluster without deadlines, cross-checked against the §IV
   event simulator: measured per-resolution mean delays track the
   simulated ones and keep the MSB-first ordering res0 < ... < final.

Run:  PYTHONPATH=src python examples/runtime_deadline.py
"""

import numpy as np

from repro.core import simulator
from repro.runtime import (RuntimeConfig, delay_table, format_delay_table,
                           run_jobs)


def part1_stall_and_deadline():
    print("=" * 72)
    print("1) Stalled worker + deadline: partial resolutions still ship")
    # worker 2 holds 1 of the 6 coded tasks (eq.(1) split [2, 3, 1]); the
    # omega = 1.5 redundancy is exactly what lets rounds fuse without it.
    cfg = RuntimeConfig(mu=(400.0, 650.0, 380.0), arrival_rate=14.0,
                        complexity=8.0, deadline=0.030, straggler="stall",
                        stall_workers=(2,), stall_seconds=2.0, seed=0)
    result, futures = run_jobs(cfg, num_jobs=30, K=64, M=8, N=8, verify=True)
    hist = result.release_histogram()
    sr = result.success_rate()
    print(f"   worker 2 stalls on every task; deadline = "
          f"{cfg.deadline * 1e3:.0f} ms from service start")
    print(f"   terminated {int(result.terminated.sum())}/{result.num_jobs} "
          f"jobs; released resolution histogram (none, res0, res1, res2): "
          f"{hist.tolist()}")
    print(f"   success rate per resolution: "
          + "  ".join(f"l{l}={sr[l]:.2f}" for l in range(len(sr))))
    errs = result.verify_errors[np.isfinite(result.verify_errors)]
    if errs.size:
        print(f"   every released resolution decode-verified vs the exact "
              f"layered oracle: max rel err {errs.max():.2e}")
    term = np.flatnonzero(result.terminated)
    if term.size:
        j = term[0]
        print(f"   e.g. job {j}: final resolution cut off, released "
              f"resolution {result.released[j]} "
              f"(ready {result.layer_compute[j, result.released[j]] * 1e3:.1f}"
              f" ms after service start)")


def part2_runtime_vs_simulator():
    print("=" * 72)
    print("2) Measured runtime vs the §IV simulator (same configuration)")
    cfg = RuntimeConfig(mu=(400.0, 650.0, 380.0), arrival_rate=8.0,
                        complexity=8.0, straggler="exp", seed=1)
    result, _ = run_jobs(cfg, num_jobs=40, K=64, M=8, N=8)
    sim = simulator.simulate(cfg.to_system_config(), 4000, layered=True,
                             seed=1)
    bounds = simulator.theory_bounds(cfg.to_system_config(),
                                     sim.service_moments(), layered=True)
    print("   measured (40 jobs, real threads, real matmuls):")
    print(format_delay_table(delay_table(result)))
    print("   simulated (4000 jobs) + eq.(4) bounds:")
    print(format_delay_table(delay_table(sim, bounds=bounds)))
    md, sd = result.mean_delay(), sim.mean_delay()
    assert np.all(np.diff(md) > 0), "measured delays must be MSB-ordered"
    print(f"   first-resolution mean delay: measured {md[0] * 1e3:.1f} ms "
          f"vs simulated {sd[0] * 1e3:.1f} ms")


if __name__ == "__main__":
    part1_stall_and_deadline()
    part2_runtime_vs_simulator()
    print("=" * 72)
    print("runtime_deadline OK")
