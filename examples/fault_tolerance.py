"""Fault-tolerance walkthrough: coded-DP pod loss + checkpoint/elastic resume.

1. Four "pods" compute MDS-coded gradient combinations (GradientCoder,
   n=4, k=3).  Kill any pod mid-step: the fusion decodes the exact
   full-batch gradient from the 3 survivors — no recompute, no straggler
   wait (the paper's erasure model at pod granularity).
2. Train a few steps, checkpoint, "crash", resume from the latest
   checkpoint via the elastic-restore path, and verify training continues
   bit-compatibly.

Run:  PYTHONPATH=src python examples/fault_tolerance.py
"""

import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.configs.base import AttentionConfig, ModelConfig, TrainConfig
from repro.core.layered_matmul import GradientCoder
from repro.launch import fault
from repro.launch.train import train_loop


def part1_coded_dp():
    print("=" * 72)
    print("1) Coded data parallelism: lose any pod, decode exact gradients")
    rng = np.random.default_rng(0)
    coder = GradientCoder(n=4, k=3)
    params = {"w": jnp.asarray(rng.normal(size=(16,)), jnp.float32)}
    shards = [jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
              for _ in range(4)]

    def loss_fn(p, batch):
        return jnp.mean((batch @ p["w"]) ** 2)

    codewords = fault.coded_dp_grads(loss_fn, params, shards, coder)
    exact = jax.tree.map(lambda *g: sum(g),
                         *[jax.grad(loss_fn)(params, b) for b in shards])
    print(f"   pods: {coder.n}, tolerate: {coder.n - coder.k} loss, "
          f"replication: {coder.replication}x data per pod")
    for lost in range(4):
        surv = [p for p in range(4) if p != lost]
        got = fault.degraded_step_grads(codewords, surv, coder)
        err = float(jnp.abs(got["w"] - exact["w"]).max())
        print(f"   pod {lost} lost -> decode from {surv}: "
              f"gradient error {err:.2e}")


def part2_checkpoint_resume():
    print("=" * 72)
    print("2) Checkpoint / crash / elastic resume")
    cfg = ModelConfig(
        name="ft-demo", family="dense", num_layers=2, d_model=64, d_ff=128,
        vocab_size=512, compute_dtype="float32", remat_policy="none",
        attention=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16),
        tie_embeddings=True)
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=5, total_steps=40)
    ckpt_dir = tempfile.mkdtemp(prefix="ft_demo_")
    try:
        out1 = train_loop(cfg, tcfg, batch=4, seq=32, steps=20,
                          ckpt_dir=ckpt_dir, ckpt_every=10, log_every=10)
        print(f"   'crash' after step 20; latest checkpoint: "
              f"step {store.latest_step(ckpt_dir)}")
        out2 = train_loop(cfg, tcfg, batch=4, seq=32, steps=40,
                          ckpt_dir=ckpt_dir, resume=True, log_every=10)
        l20 = out1["losses"][-1][1]
        l40 = out2["losses"][-1][1]
        print(f"   resumed and trained to step 40: loss {l20:.3f} -> "
              f"{l40:.3f}")
        assert l40 < l20 + 0.05
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    part1_coded_dp()
    part2_checkpoint_resume()
    print("=" * 72)
    print("fault_tolerance OK")
