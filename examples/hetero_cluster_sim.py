"""Reproduce the paper's §IV evaluation end-to-end (Figs 2a/2b/3a/3b).

Thin driver over benchmarks/paper_figures.py; writes CSVs to results/ and
prints each figure's claim-check.  ~2 minutes.

Run:  PYTHONPATH=src python examples/hetero_cluster_sim.py [--fast]
"""

import argparse
import sys


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()

    sys.path.insert(0, ".")
    from benchmarks import paper_figures
    out = paper_figures.run_all(fast=args.fast)

    print("\nsummary of paper-claim checks:")
    print(f"  Fig2a bound tightness @ omega=1.06: "
          f"{out['fig2a']['tight_at_1.06'] * 100:.1f}% gap (paper: ~tight)")
    print(f"  Fig2b strictly-ordered realizations: "
          f"{out['fig2b']['frac_ordered'] * 100:.0f}%")
    print(f"  Fig3b success@deadline=10: l0/l2/no-layer = "
          f"{out['fig3b']['sr_at_10']}")


if __name__ == "__main__":
    main()
