"""Serving with deadline-bounded progressive resolution (paper §IV, on-chip).

Batched greedy decoding where the LM head is digit-plane decomposed
(LayeredLinear): each step computes logits MSB-plane-first and releases the
best resolution the per-step budget allows.  Shows token agreement with the
full-resolution decode as the budget grows — the paper's success-rate curve
transplanted to serving quality.

Run:  PYTHONPATH=src python examples/serve_progressive.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.launch.serve import ProgressiveServer
from repro.models import transformer as T


def main():
    arch = "llama3-8b"
    cfg = registry.get_smoke_config(arch)
    print(f"serving reduced {arch} ({cfg.num_layers}L d={cfg.d_model}) "
          f"with a 4-plane layered LM head")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    server = ProgressiveServer(cfg, params, m=4, d=4)

    rng = np.random.default_rng(0)
    B, prompt_len, gen = 4, 32, 24
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, prompt_len)),
                         jnp.int32)
    max_len = prompt_len + gen

    _, caches = server.prefill(tokens, max_len)
    full, _ = server.decode(tokens[:, -1:], caches, prompt_len, gen)

    print(f"{'budget':>8} {'resolutions':>12} {'agreement with full':>22}")
    for budget in (1, 2, 3, 4):
        _, caches = server.prefill(tokens, max_len)
        out, stats = server.decode(tokens[:, -1:], caches, prompt_len, gen,
                                   layer_budget=budget)
        agree = float((np.asarray(out) == np.asarray(full)).mean())
        print(f"{budget:>8} {stats.released_at_layer[0]:>12} "
              f"{100 * agree:>20.1f}%")
    print("\n-> a deadline that only affords the MSB planes still serves "
          "mostly-correct tokens;\n   budget=m reproduces the exact "
          "full-resolution decode (paper's no-cost layering claim).")


if __name__ == "__main__":
    main()
